"""Serving engine + RE-constrained decoding (the paper as a serving feature)."""

import re

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.reference import ParallelArtifacts
from repro.models.model import init_params
from repro.serve.engine import ServeEngine, TokenDFA, byte_vocab


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_token_dfa_semantics():
    art = ParallelArtifacts.generate("(ab|a)*c")
    tdfa = TokenDFA.from_matrices(art.matrices, byte_vocab(128))
    s = tdfa.initial
    # 'a' allowed, 'b' not, from start
    assert tdfa.delta[s, ord("a")] >= 0
    assert tdfa.delta[s, ord("b")] == -1
    # after "ab", 'a' or 'c'
    s2 = tdfa.delta[tdfa.delta[s, ord("a")], ord("b")]
    assert s2 >= 0
    assert tdfa.delta[s2, ord("a")] >= 0 and tdfa.delta[s2, ord("c")] >= 0
    # final only after 'c'
    s3 = tdfa.delta[s2, ord("c")]
    assert tdfa.final[s3]
    assert not tdfa.final[s2]


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_constrained_generation_always_matches(setup, seed):
    cfg, params = setup
    pat = "(ab|a)*c"
    art = ParallelArtifacts.generate(pat)
    tdfa = TokenDFA.from_matrices(art.matrices, byte_vocab(cfg.vocab_size))
    eng = ServeEngine(cfg, params, max_seq=64, batch=2, eos_id=0)
    prompts = np.array([[ord("a")], [ord("a")]], np.int32)
    res = eng.generate(prompts, max_new=10, temperature=1.0, seed=seed, constraint=tdfa)
    for row in res.tokens:
        s = ""
        for c in row:
            if c == 0:
                break
            s += chr(int(c))
        assert re.fullmatch("(ab|a)*c", s), s


def test_dead_end_emits_eos_not_token_zero(setup):
    """Regression: a constrained row whose mask is all-false used to write
    ``argmax(-inf) == 0`` (an arbitrary token) into the output; stuck rows
    must emit EOS instead."""
    cfg, params = setup
    art = ParallelArtifacts.generate("ab")
    # vocab with NO token for 'b': after generating 'a' the row is stuck —
    # every continuation dead, and the non-final state forbids EOS too
    vocab = [b"\xff\xff"] * cfg.vocab_size
    vocab[1] = b"a"
    tdfa = TokenDFA.from_matrices(art.matrices, vocab)
    eos_id = 5
    eng = ServeEngine(cfg, params, max_seq=16, batch=2, eos_id=eos_id)
    prompts = np.array([[1], [1]], np.int32)
    res = eng.generate(prompts, max_new=4, temperature=0.0, constraint=tdfa)
    assert res.tokens.shape == (2, 2)            # stuck at step 2 → early stop
    assert np.all(res.tokens[:, 0] == 1)         # only 'a' is ever allowed
    assert np.all(res.tokens[:, 1] == eos_id)    # dead end → EOS, never 0
    assert not res.accepted.any()                # "a" does not match "ab"


def test_unconstrained_generation_shapes(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_seq=32, batch=3)
    prompts = np.array([[1, 2], [3, 4], [5, 6]], np.int32)
    res = eng.generate(prompts, max_new=5, temperature=0.0)
    assert res.tokens.shape == (3, 5)
    # greedy decode is deterministic
    res2 = eng.generate(prompts, max_new=5, temperature=0.0)
    assert np.array_equal(res.tokens, res2.tokens)
