"""JAX parallel engine (core/engine.py) vs serial oracle.

The distributed (mesh) path lives in tests/test_distributed.py."""

import numpy as np
import pytest

from repro.core.engine import EngineTables, ParserEngine
from repro.core.reference import ParallelArtifacts
from repro.core.serial import parse_serial_matrix
from repro.data.regen import random_regex, sample_string


@pytest.fixture(scope="module")
def art():
    return ParallelArtifacts.generate("(a|b|ab)+")


@pytest.fixture(scope="module", params=["jnp", "pallas"])
def engine(art, request):
    return ParserEngine(art.matrices, backend=request.param)


@pytest.mark.parametrize("text,c", [
    ("abab", 1), ("abab", 2), ("abab", 4), ("ababab", 3),
    ("", 2), ("b", 1), ("ba", 2), ("a" * 23, 5),
])
def test_engine_matches_serial(art, engine, text, c):
    ref = parse_serial_matrix(art.matrices, text)
    got = engine.parse(text, n_chunks=c)
    assert np.array_equal(ref.columns, got.columns), (text, c)


def test_identity_padding_is_noop(art, engine):
    """PAD-class chunks (identity matrices) never change the SLPF."""
    text = "ababa"
    a = engine.parse(text, n_chunks=2)   # k=3, 1 pad char
    b = engine.parse(text, n_chunks=5)   # k=1, no pad
    c = engine.parse(text, n_chunks=4)   # k=2, 3 pads
    assert np.array_equal(a.columns, b.columns)
    assert np.array_equal(a.columns, c.columns)


def test_lane_padding_invariance(art):
    """Padding ℓ to 128 lanes (kernel alignment) is semantics-free."""
    e32 = ParserEngine(art.matrices, lane_pad=32)
    e128 = ParserEngine(art.matrices, lane_pad=128)
    for text in ["abab", "ba", "aabba"]:
        assert np.array_equal(
            e32.parse(text, 3).columns, e128.parse(text, 3).columns
        )


def test_property_engine_equals_serial():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    from repro.core.numbering import number_regex
    from repro.core.segments import compute_segments

    @hyp.given(st.integers(0, 5_000), st.integers(3, 8), st.integers(1, 5))
    @hyp.settings(max_examples=20, deadline=None)
    def run(seed, size, c):
        rng = np.random.Generator(np.random.Philox(seed))
        ast = random_regex(size, rng)
        art = ParallelArtifacts.generate(compute_segments(number_regex(ast)))
        eng = ParserEngine(art.matrices)
        text = sample_string(ast, rng)[:10]
        ref = parse_serial_matrix(art.matrices, text)
        got = eng.parse(text, n_chunks=c)
        assert np.array_equal(ref.columns, got.columns)

    run()
