"""SLPF forest API: counting, enumeration, matches, packing, compression."""

import numpy as np
import pytest

from repro.core.matrices import pack_bits, unpack_bits
from repro.core.serial import SerialParser
from repro.core.slpf import compress


@pytest.fixture(scope="module")
def parser():
    return SerialParser("(a|b|ab)+")


def test_count_vs_enumeration(parser):
    s = parser.parse("abab")
    trees = list(s.iter_trees())
    assert s.count_trees() == len(trees) == 4
    # each enumerated path really is a tree: consecutive segments connected
    for path in trees:
        for r in range(len(path) - 1):
            assert path[r + 1] in s.table.delta(path[r], int(s.classes[r]))


def test_iter_trees_limit(parser):
    s = parser.parse("ababab")
    assert len(list(s.iter_trees(limit=3))) == 3


def test_lst_strings_are_balanced(parser):
    s = parser.parse("abab")
    for path in s.iter_trees():
        lst = s.lst_string(path)
        depth = 0
        for i, ch in enumerate(lst):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
            assert depth >= 0
        assert depth == 0


def test_get_matches_groups():
    """App. A extra parens: group spans extracted from the SLPF."""
    p = SerialParser("x(ab)+y")
    s = p.parse("xababy")
    # the Group node wraps "ab"; find its paren number
    from repro.core.numbering import OPEN, OP_GROUP

    gnum = next(
        sym.num for sym in p.table.numbered.symbols
        if sym.kind == OPEN and sym.op == OP_GROUP
    )
    spans = s.get_matches(gnum)
    assert (1, 3) in spans and (3, 5) in spans


def test_get_children_structure(parser):
    s = parser.parse("ab")
    path = next(s.iter_trees())
    kids = s.get_children(path)
    # every span well-formed and within text bounds
    for num, a, b in kids:
        assert 0 <= a <= b <= s.n


def test_pack_roundtrip(parser):
    s = parser.parse("ababab")
    packed = s.pack()
    from repro.core.slpf import SLPF

    s2 = SLPF.from_packed(s.table, packed, s.classes)
    assert np.array_equal(s.columns, s2.columns)


def test_pack_bits_roundtrip_property():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.binary(min_size=0, max_size=64))
    @hyp.settings(max_examples=30, deadline=None)
    def run(data):
        arr = np.frombuffer(data, dtype=np.uint8).astype(bool)
        n = len(arr)
        if n == 0:
            return
        packed = pack_bits(arr[None, :], axis=-1)
        un = unpack_bits(packed, n, axis=-1)
        assert np.array_equal(un[0], arr)

    run()


def test_compression_roundtrip(parser):
    """App. C: SLPF-DFA compression reconstructs the exact forest."""
    s = parser.parse("ababababab")
    c = compress(s)
    s2 = c.reconstruct()
    assert np.array_equal(s.columns, s2.columns)
    # compressed size is independent of text length (states interned)
    s_long = parser.parse("ab" * 200)
    c_long = compress(s_long)
    assert len(c_long.states) <= 8  # few distinct columns on periodic text
